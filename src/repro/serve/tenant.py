"""Multi-tenant metric serving: N learned metrics over one shared gallery.

The paper's training side produces *many* metric factors — one per
product surface, per experiment arm, per region — but the raw gallery
they rank is the same feature store. Running one full serving stack per
metric multiplies the dominant cost (resident gallery bytes) by the
tenant count for no reason: the raw rows are identical, only the
projection through L differs.

``TenantRouter`` keeps the raw rows **once** and gives every tenant its
own *projected view*:

  * each tenant owns an ``(d_out, d_in)`` factor L, a backend choice
    (exact / ivf / ivfpq) with build kwargs, and its own
    ``RetrievalEngine`` (hot-query LRU included) over a frozen view
    built by projecting the shared rows through its L — cold tenants
    pay the build lazily on first query (or eagerly via ``warm``);
  * tenant engines record into ``registry.scoped(tenant=name)``, so one
    base ``MetricsRegistry`` carries every tenant's ``engine_*`` series
    distinguished by the ``tenant`` label — no per-tenant registries to
    merge, and ``check_obs`` can assert the label is always present;
  * per-tenant SLO: a priority class + deadline that ``submit`` maps
    into the attached ``RequestScheduler`` via its tenant routes
    (batches never mix tenants — one engine call per batch);
  * gallery mutation (``extend`` / ``remove``) bumps a generation
    counter; stale warm views rebuild lazily on next use. External row
    ids are stable positions in the shared store, so results compare
    across tenants and survive rebuilds;
  * ``save_tenants`` / ``load_tenants`` persist the whole tenant set —
    shared rows once plus each warm tenant's built view through the
    snapshot machinery, gated on reload by the manifest L fingerprint
    (``TenantFingerprintError``);
  * ``ShadowArm``: a tenant registers a *candidate* L that receives
    mirrored (deterministically sampled) traffic. The arm accumulates
    overlap-vs-live and latency deltas in the registry; ``promote``
    atomically repoints the live engine at the shadow view — the same
    build the trainer's ``swap_metric`` would produce, bit for bit —
    closing the loop with ``mining.ClosedLoopTrainer``.
"""

from __future__ import annotations

import json
import os
import re
import threading
from concurrent.futures import Future
from typing import Dict, Optional, Sequence

import numpy as np

from repro.obs import MetricsRegistry, Tracer, index_memory
from repro.serve.clock import Clock, SystemClock
from repro.serve.engine import RetrievalEngine
from repro.serve.index import ExactIndex
from repro.serve.ivf import IVFIndex
from repro.serve.pq import IVFPQIndex
from repro.serve.snapshot import l_fingerprint, load_index, save_index

_NAME_RE = re.compile(r"^[A-Za-z0-9_-]+$")
_BACKENDS = ("exact", "ivf", "ivfpq")
TENANTS_MANIFEST = "tenants.json"


class TenantError(ValueError):
    """Tenant-layer misuse: unknown/duplicate tenant, bad name, no
    scheduler attached, dimension mismatch."""


class TenantFingerprintError(TenantError):
    """A persisted view's L fingerprint does not match the tenant's
    factor — the snapshot was built under a different metric."""


class Tenant:
    """One tenant's serving state. Created via ``TenantRouter.add_tenant``
    — not directly. ``engine`` is None until the first build (cold)."""

    __slots__ = ("name", "L", "fingerprint", "backend", "build_kwargs",
                 "k_top", "cache_size", "priority", "deadline_s",
                 "engine", "ids", "built_generation", "shadow",
                 "n_requests")

    def __init__(self, name, L, backend, build_kwargs, k_top, cache_size,
                 priority, deadline_s):
        self.name = name
        self.L = np.asarray(L, np.float32)
        self.fingerprint = l_fingerprint(self.L)
        self.backend = backend
        self.build_kwargs = dict(build_kwargs)
        self.k_top = k_top
        self.cache_size = cache_size
        self.priority = priority
        self.deadline_s = deadline_s
        self.engine: Optional[RetrievalEngine] = None
        # view position -> shared-store row id, frozen at build time
        self.ids: Optional[np.ndarray] = None
        self.built_generation = -1
        self.shadow: Optional[ShadowArm] = None
        self.n_requests = 0

    @property
    def warm(self) -> bool:
        return self.engine is not None


class ShadowArm:
    """A candidate metric riding a live tenant's traffic.

    Mirrored queries (deterministic accumulator at ``sample_rate``) run
    against a view built under the candidate L; per-query top-k overlap
    with the live answer and the live/shadow latency totals accumulate
    here and in the registry. The arm never answers client traffic —
    ``promote`` makes it live."""

    __slots__ = ("L", "fingerprint", "sample_rate", "engine", "ids",
                 "built_generation", "_acc", "n_mirrored", "overlap_sum",
                 "n_rows", "live_s", "shadow_s")

    def __init__(self, L, sample_rate: float):
        self.L = np.asarray(L, np.float32)
        self.fingerprint = l_fingerprint(self.L)
        self.sample_rate = float(sample_rate)
        self.engine: Optional[RetrievalEngine] = None
        self.ids: Optional[np.ndarray] = None
        self.built_generation = -1
        self._acc = 0.0         # deterministic sampler: acc += rate
        self.n_mirrored = 0
        self.overlap_sum = 0.0  # sum of per-row |live ∩ shadow| / k
        self.n_rows = 0
        self.live_s = 0.0
        self.shadow_s = 0.0

    def take(self) -> bool:
        """Mirror this request? Deterministic: fires every time the
        accumulated rate crosses 1 (rate 0.25 -> every 4th request)."""
        self._acc += self.sample_rate
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False

    def stats(self) -> dict:
        mean = (self.overlap_sum / self.n_rows) if self.n_rows else 0.0
        ratio = (self.shadow_s / self.live_s) if self.live_s > 0 else 0.0
        return {"fingerprint": self.fingerprint,
                "sample_rate": self.sample_rate,
                "n_mirrored": self.n_mirrored,
                "overlap_at_k": mean,
                "latency_ratio": ratio,
                "warm": self.engine is not None}


class TenantRouter:
    """N learned metrics over one shared raw gallery.

    Thread-safety: gallery mutation, tenant registration, and view
    (re)builds serialize on an internal lock; the per-tenant engines
    follow the engine's own contract (serve from one worker — the
    attached scheduler provides exactly that; the router's direct
    ``search`` is for tests, tools, and single-threaded callers).
    """

    def __init__(self, gallery, *,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 clock: Optional[Clock] = None,
                 k_top: int = 10):
        rows = np.asarray(gallery, np.float32)
        if rows.ndim != 2:
            raise TenantError(f"gallery must be (M, d_in), got shape "
                              f"{rows.shape}")
        self._rows = rows.copy()            # the single shared raw store
        self._dead = np.zeros(rows.shape[0], dtype=bool)
        self._generation = 0
        self.k_top = k_top
        self.clock = clock if clock is not None else SystemClock()
        self.registry = (registry if registry is not None
                         else MetricsRegistry(clock=self.clock))
        self.tracer = (tracer if tracer is not None
                       else Tracer(clock=self.clock, sample_rate=0.0))
        self.scheduler = None
        self._tenants: Dict[str, Tenant] = {}
        self._lock = threading.RLock()
        r = self.registry
        self._c_requests = r.counter(
            "tenant_requests_total", "router requests by tenant",
            labelnames=("tenant",))
        self._g_warm = r.gauge(
            "tenant_warm", "1 when the tenant's view is built",
            labelnames=("tenant",))
        self._c_mirrored = r.counter(
            "shadow_mirrored_total", "queries mirrored to the shadow arm",
            labelnames=("tenant",))
        self._g_overlap = r.gauge(
            "shadow_overlap_at_k",
            "running mean top-k overlap of shadow vs live answers",
            labelnames=("tenant",))
        self._g_lat_ratio = r.gauge(
            "shadow_latency_ratio",
            "shadow / live accumulated search seconds",
            labelnames=("tenant",))
        self._c_promotions = r.counter(
            "tenant_promotions_total", "shadow arms promoted to live",
            labelnames=("tenant",))

    # -- gallery ------------------------------------------------------------

    @property
    def d_in(self) -> int:
        return self._rows.shape[1]

    @property
    def gallery_rows(self) -> int:
        return self._rows.shape[0]

    @property
    def live_rows(self) -> int:
        return int((~self._dead).sum())

    @property
    def generation(self) -> int:
        return self._generation

    def extend(self, rows) -> np.ndarray:
        """Append raw rows to the shared store. Returns their (stable)
        ids. Warm views go stale and rebuild lazily on next use."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.d_in:
            raise TenantError(f"rows must be (n, {self.d_in}), got shape "
                              f"{rows.shape}")
        with self._lock:
            start = self._rows.shape[0]
            self._rows = np.concatenate([self._rows, rows])
            self._dead = np.concatenate(
                [self._dead, np.zeros(rows.shape[0], dtype=bool)])
            self._generation += 1
            return np.arange(start, start + rows.shape[0], dtype=np.int64)

    def remove(self, ids: Sequence[int]) -> int:
        """Tombstone rows by id; returns how many were newly dead."""
        with self._lock:
            ids = np.asarray(ids, np.int64)
            if ids.size and (ids.min() < 0
                             or ids.max() >= self._rows.shape[0]):
                raise TenantError(f"row id out of range [0, "
                                  f"{self._rows.shape[0]})")
            newly = int((~self._dead[ids]).sum())
            self._dead[ids] = True
            if newly:
                self._generation += 1
            return newly

    # -- tenants ------------------------------------------------------------

    def add_tenant(self, name: str, L, *, backend: str = "exact",
                   build_kwargs: Optional[dict] = None,
                   k_top: Optional[int] = None,
                   cache_size: int = 1024,
                   priority: str = "interactive",
                   deadline_s: Optional[float] = None) -> Tenant:
        """Register a tenant (cold — no view built yet).

        Args:
          name: ``[A-Za-z0-9_-]+`` (``#`` is reserved for shadow scopes).
          L: (d_out, d_in) metric factor; d_in must match the gallery.
          backend: "exact" | "ivf" | "ivfpq" (view type built on warm).
          build_kwargs: forwarded to the backend's ``build`` (n_clusters,
            nprobe, rerank_depth, ...). Builds are deterministic
            (seed=0 default), which is what makes shadow promotion
            bit-identical to a fresh build.
          k_top / cache_size: per-tenant engine shape.
          priority / deadline_s: the tenant's SLO — submit() maps these
            into the attached scheduler's priority classes.
        """
        if not _NAME_RE.match(name or ""):
            raise TenantError(f"invalid tenant name {name!r} (want "
                              f"[A-Za-z0-9_-]+)")
        if backend not in _BACKENDS:
            raise TenantError(f"unknown backend {backend!r} "
                              f"(have {_BACKENDS})")
        L = np.asarray(L, np.float32)
        if L.ndim != 2 or L.shape[1] != self.d_in:
            raise TenantError(f"L must be (d_out, {self.d_in}), got "
                              f"shape {L.shape}")
        with self._lock:
            if name in self._tenants:
                raise TenantError(f"tenant {name!r} already registered")
            t = Tenant(name, L, backend, build_kwargs or {},
                       self.k_top if k_top is None else k_top,
                       cache_size, priority, deadline_s)
            self._tenants[name] = t
        self._g_warm.set(0, tenant=name)
        self.registry.event("tenant_add", tenant=name, backend=backend,
                            fingerprint=t.fingerprint)
        return t

    def tenant(self, name: str) -> Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise TenantError(f"unknown tenant {name!r} "
                              f"(have {sorted(self._tenants)})")
        return t

    def tenants(self) -> tuple:
        return tuple(self._tenants)

    def _build_view(self, L, backend: str, kwargs: dict):
        """(index, ids): project the live shared rows through L into a
        frozen view. Deterministic for fixed (rows, L, kwargs)."""
        live = np.flatnonzero(~self._dead).astype(np.int64)
        rows = self._rows[live]
        if backend == "exact":
            view = ExactIndex.build(L, rows)
        elif backend == "ivf":
            view = IVFIndex.build(L, rows, **kwargs)
        else:
            view = IVFPQIndex.build(L, rows, **kwargs)
        return view, live

    def _attach_view(self, t: Tenant, scope: str, arm, view, ids) -> None:
        """Point ``t`` (or its shadow ``arm``) at a built view, creating
        the scoped engine on first warm and repointing the index (LRU
        flush via identity change) thereafter."""
        holder = arm if arm is not None else t
        if holder.engine is None:
            holder.engine = RetrievalEngine(
                view, k_top=t.k_top, cache_size=t.cache_size,
                registry=self.registry.scoped(tenant=scope),
                tracer=self.tracer, clock=self.clock)
        else:
            holder.engine.index = view      # identity change flushes LRU
        holder.ids = ids
        holder.built_generation = self._generation

    def warm(self, name: str) -> Tenant:
        """Build (or freshen) the tenant's projected view now instead of
        on first query. Idempotent when already fresh."""
        t = self.tenant(name)
        with self._lock:
            if t.engine is None or t.built_generation != self._generation:
                view, ids = self._build_view(t.L, t.backend,
                                             t.build_kwargs)
                self._attach_view(t, t.name, None, view, ids)
                if self.scheduler is not None:
                    # (re)derive the route ladder from the fresh view
                    self.scheduler.add_route(t.name, t.engine)
                self._g_warm.set(1, tenant=t.name)
                self.registry.event("tenant_warm", tenant=t.name,
                                    generation=self._generation,
                                    rows=int(ids.shape[0]))
        return t

    def _warm_shadow(self, t: Tenant) -> ShadowArm:
        arm = t.shadow
        with self._lock:
            if (arm.engine is None
                    or arm.built_generation != self._generation):
                view, ids = self._build_view(arm.L, t.backend,
                                             t.build_kwargs)
                self._attach_view(t, f"{t.name}#shadow", arm, view, ids)
        return arm

    # -- serving ------------------------------------------------------------

    def _translate(self, t_ids: np.ndarray, idxs: np.ndarray):
        """View positions -> stable shared-store ids (-1 stays -1: IVF
        pads short probes with -1)."""
        safe = np.clip(idxs, 0, t_ids.shape[0] - 1)
        return np.where(idxs >= 0, t_ids[safe], -1).astype(np.int64)

    def search(self, name: str, queries, k_top: Optional[int] = None,
               **topk_kw):
        """Synchronous per-tenant search: lazy-warms, serves from the
        tenant engine, translates view positions to stable row ids, and
        mirrors to the shadow arm when one is registered. queries (d,)
        or (n, d); returns (dists, ids) shaped like ``engine.search``."""
        t = self.warm(name)
        self._c_requests.inc(tenant=name)
        t.n_requests += 1
        t0 = self.clock.now()
        dists, idxs = t.engine.search(queries, k_top=k_top, **topk_kw)
        elapsed = self.clock.now() - t0
        ids = self._translate(t.ids, idxs)
        if t.shadow is not None and t.shadow.take():
            k = t.k_top if k_top is None else k_top
            self._mirror(t, queries, k, ids, elapsed, topk_kw)
        return dists, ids

    def submit(self, name: str, query, k_top: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Future:
        """Submit one (d,) query through the attached scheduler under the
        tenant's route + SLO (priority class, deadline). Returns a Future
        resolving to (dists (k,), ids (k,)) with stable row ids; shadow
        mirroring happens on completion, off the client's future."""
        if self.scheduler is None:
            raise TenantError("no scheduler attached "
                              "(attach_scheduler first)")
        t = self.warm(name)
        self._c_requests.inc(tenant=name)
        t.n_requests += 1
        dl = t.deadline_s if deadline_s is None else deadline_s
        t0 = self.clock.now()
        inner = self.scheduler.submit(query, k_top=k_top,
                                      priority=t.priority,
                                      deadline_s=dl, route=t.name)
        outer: Future = Future()
        outer.set_running_or_notify_cancel()
        q = np.asarray(query, np.float32)
        k = t.k_top if k_top is None else k_top
        t_ids = t.ids               # frozen: rebuilds swap the array out

        def _done(f: Future) -> None:
            exc = f.exception()
            if exc is not None:
                outer.set_exception(exc)
                return
            dists, idxs = f.result()
            ids = self._translate(t_ids, idxs)
            outer.set_result((dists, ids))
            if t.shadow is not None and t.shadow.take():
                self._mirror(t, q, k, ids[None, :],
                             self.clock.now() - t0, {})

        inner.add_done_callback(_done)
        return outer

    def _mirror(self, t: Tenant, queries, k: int, live_ids, live_elapsed,
                topk_kw) -> None:
        """Run the mirrored query on the shadow view and fold the
        overlap + latency deltas into the arm and the registry. Shadow
        failures are recorded, never surfaced to the live path."""
        arm = t.shadow
        try:
            self._warm_shadow(t)
            t0 = self.clock.now()
            _, s_idxs = arm.engine.search(queries, k_top=k, **topk_kw)
            s_elapsed = self.clock.now() - t0
            s_ids = self._translate(arm.ids, s_idxs)
        except Exception as e:      # pragma: no cover - defensive
            self.registry.event("shadow_error", tenant=t.name,
                                error=repr(e))
            return
        live_ids = np.atleast_2d(np.asarray(live_ids))
        s_ids = np.atleast_2d(s_ids)
        for row in range(live_ids.shape[0]):
            a = set(int(i) for i in live_ids[row] if i >= 0)
            b = set(int(i) for i in s_ids[row] if i >= 0)
            arm.overlap_sum += len(a & b) / max(k, 1)
            arm.n_rows += 1
        arm.n_mirrored += 1
        arm.live_s += live_elapsed
        arm.shadow_s += s_elapsed
        self._c_mirrored.inc(tenant=t.name)
        st = arm.stats()
        self._g_overlap.set(st["overlap_at_k"], tenant=t.name)
        self._g_lat_ratio.set(st["latency_ratio"], tenant=t.name)

    # -- shadow lifecycle ----------------------------------------------------

    def register_shadow(self, name: str, L, *,
                        sample_rate: float = 0.25) -> ShadowArm:
        """Put a candidate L in shadow behind ``name``. One arm per
        tenant (re-registering replaces it). The arm's view builds lazily
        on the first mirrored query."""
        if not 0.0 < sample_rate <= 1.0:
            raise TenantError(f"sample_rate must be in (0, 1], got "
                              f"{sample_rate}")
        t = self.tenant(name)
        L = np.asarray(L, np.float32)
        if L.ndim != 2 or L.shape[1] != self.d_in:
            raise TenantError(f"L must be (d_out, {self.d_in}), got "
                              f"shape {L.shape}")
        with self._lock:
            t.shadow = ShadowArm(L, sample_rate)
        self.registry.event("shadow_register", tenant=name,
                            fingerprint=t.shadow.fingerprint,
                            sample_rate=sample_rate)
        return t.shadow

    def promote(self, name: str) -> Tenant:
        """Make the shadow arm live, atomically from the caller's view:
        the tenant's engine is repointed at the shadow's view (the same
        deterministic build a fresh ``swap_metric`` rebuild would
        produce — bit-identical answers), its LRU flushes on the
        identity change, the scheduler route re-derives its ladder, and
        the arm is cleared. The engine object survives, so held routes
        and ``engine.stats()`` readers stay valid."""
        t = self.tenant(name)
        with self._lock:
            arm = t.shadow
            if arm is None:
                raise TenantError(f"tenant {name!r} has no shadow arm")
            self._warm_shadow(t)    # build now if no traffic mirrored yet
            stats = arm.stats()
            t.L = arm.L
            t.fingerprint = arm.fingerprint
            if t.engine is None:
                # promoted before ever serving live: the arm's engine is
                # scoped "#shadow", and metric series cannot be renamed —
                # drop it and warm fresh under the live scope (same
                # deterministic build, so answers are identical anyway)
                t.shadow = None
                self.warm(name)     # RLock: safe under self._lock
                self._c_promotions.inc(tenant=name)
                return t
            t.engine.index = arm.engine.index   # identity change: flush
            t.ids = arm.ids
            t.built_generation = arm.built_generation
            t.shadow = None
            if self.scheduler is not None:
                self.scheduler.add_route(t.name, t.engine)
        self._c_promotions.inc(tenant=name)
        self.registry.event("tenant_promote", tenant=name,
                            fingerprint=t.fingerprint,
                            n_mirrored=stats["n_mirrored"],
                            overlap_at_k=stats["overlap_at_k"],
                            latency_ratio=stats["latency_ratio"])
        return t

    # -- scheduler ----------------------------------------------------------

    def attach_scheduler(self, scheduler) -> None:
        """Wire a RequestScheduler as the traffic front end: every warm
        tenant gets a route now; tenants warmed later register theirs at
        build time. Construct the scheduler with
        ``registry=router.registry`` so its frontend_* series stay
        unscoped on the shared base."""
        with self._lock:
            self.scheduler = scheduler
            for t in self._tenants.values():
                if t.engine is not None:
                    scheduler.add_route(t.name, t.engine)

    # -- accounting ----------------------------------------------------------

    def memory(self) -> dict:
        """Resident bytes: the shared raw store counted ONCE plus each
        warm view's index_memory total (the multi-tenant win: N tenants
        share one gallery instead of N raw copies)."""
        out = {"gallery": int(self._rows.nbytes + self._dead.nbytes),
               "tenants": {}, "shadows": {}}
        for name, t in self._tenants.items():
            if t.engine is not None:
                out["tenants"][name] = int(
                    sum(index_memory(t.engine.index).values()))
            if t.shadow is not None and t.shadow.engine is not None:
                out["shadows"][name] = int(
                    sum(index_memory(t.shadow.engine.index).values()))
        out["total"] = (out["gallery"] + sum(out["tenants"].values())
                        + sum(out["shadows"].values()))
        return out

    def observability(self) -> dict:
        """Router-level block for logs/benchmarks: gallery shape,
        per-tenant serving state (+ engine stats when warm, + shadow
        deltas when registered), and the byte accounting."""
        tenants = {}
        for name, t in self._tenants.items():
            block = {"warm": t.warm, "backend": t.backend,
                     "fingerprint": t.fingerprint,
                     "n_requests": t.n_requests,
                     "priority": t.priority,
                     "l_shape": list(t.L.shape)}
            if t.engine is not None:
                es = t.engine.stats()
                block.update(
                    view_rows=es["gallery_size"], qps=es["qps"],
                    cache_hits=es["cache_hits"],
                    cache_misses=es["cache_misses"],
                    stale=t.built_generation != self._generation)
            if t.shadow is not None:
                block["shadow"] = t.shadow.stats()
            tenants[name] = block
        return {"gallery_rows": self.gallery_rows,
                "live_rows": self.live_rows,
                "generation": self._generation,
                "d_in": self.d_in,
                "tenants": tenants,
                "memory": self.memory()}


# -- persistence -------------------------------------------------------------

def save_tenants(router: TenantRouter, out_dir: str) -> dict:
    """Persist a tenant set: the shared raw store once (gallery.npz),
    every tenant's factor (factors.npz), each warm *fresh* tenant's
    built view through ``save_index`` (tenant_<name>/ with its own
    manifest + ids.npz), and tenants.json last (its presence marks the
    save complete). Stale views are persisted as cold — reloading
    rebuilds them, which is what staleness means. Returns the manifest
    dict."""
    os.makedirs(out_dir, exist_ok=True)
    stale = os.path.join(out_dir, TENANTS_MANIFEST)
    if os.path.isfile(stale):
        os.remove(stale)
    with router._lock:
        np.savez(os.path.join(out_dir, "gallery.npz"),
                 rows=router._rows, dead=router._dead)
        np.savez(os.path.join(out_dir, "factors.npz"),
                 **{t.name: t.L for t in router._tenants.values()})
        manifest = {"format": 1, "k_top": router.k_top,
                    "generation": router._generation, "tenants": {}}
        for name, t in router._tenants.items():
            entry = {"backend": t.backend,
                     "build_kwargs": t.build_kwargs,
                     "k_top": t.k_top, "cache_size": t.cache_size,
                     "priority": t.priority, "deadline_s": t.deadline_s,
                     "fingerprint": t.fingerprint, "view": None}
            fresh = (t.engine is not None
                     and t.built_generation == router._generation)
            if fresh:
                sub = f"tenant_{name}"
                subdir = os.path.join(out_dir, sub)
                os.makedirs(subdir, exist_ok=True)
                # ids before save_index: the view manifest is the
                # completeness marker for the whole subdir
                np.savez(os.path.join(subdir, "ids.npz"), ids=t.ids)
                save_index(t.engine.index, subdir,
                           registry=router.registry)
                entry["view"] = sub
            manifest["tenants"][name] = entry
    path = os.path.join(out_dir, TENANTS_MANIFEST)
    with open(path + ".tmp", "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    os.replace(path + ".tmp", path)
    router.registry.event("tenants_save", dir=out_dir,
                          n_tenants=len(manifest["tenants"]))
    return manifest


def load_tenants(snapshot_dir: str, *,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 clock: Optional[Clock] = None) -> TenantRouter:
    """Reconstruct a ``save_tenants`` set: shared store, every tenant's
    registration, and each persisted view attached WITHOUT re-projecting
    (the snapshot fingerprint is checked against the tenant's saved
    factor — ``TenantFingerprintError`` on mismatch, which means the
    snapshot directory was tampered with or mixed between saves)."""
    path = os.path.join(snapshot_dir, TENANTS_MANIFEST)
    if not os.path.isfile(path):
        raise FileNotFoundError(
            f"no tenants manifest at {path} (incomplete or missing "
            f"save)")
    with open(path) as f:
        manifest = json.load(f)
    with np.load(os.path.join(snapshot_dir, "gallery.npz")) as z:
        rows, dead = z["rows"], z["dead"]
    with np.load(os.path.join(snapshot_dir, "factors.npz")) as z:
        factors = {k: z[k] for k in z.files}
    router = TenantRouter(rows, registry=registry, tracer=tracer,
                          clock=clock, k_top=int(manifest["k_top"]))
    router._dead = dead.astype(bool)
    router._generation = int(manifest["generation"])
    for name, entry in manifest["tenants"].items():
        t = router.add_tenant(
            name, factors[name], backend=entry["backend"],
            build_kwargs=entry["build_kwargs"], k_top=entry["k_top"],
            cache_size=entry["cache_size"], priority=entry["priority"],
            deadline_s=entry["deadline_s"])
        if t.fingerprint != entry["fingerprint"]:
            raise TenantFingerprintError(
                f"tenant {name!r}: saved factor fingerprints "
                f"{t.fingerprint}, manifest says "
                f"{entry['fingerprint']} — factors.npz and "
                f"tenants.json are from different saves")
        if entry["view"] is not None:
            attach_view(router, name,
                        os.path.join(snapshot_dir, entry["view"]))
    router.registry.event("tenants_load", dir=snapshot_dir,
                          n_tenants=len(manifest["tenants"]))
    return router


def attach_view(router: TenantRouter, name: str,
                view_dir: str) -> Tenant:
    """Attach a persisted view (a ``save_index`` directory + ids.npz) to
    a registered tenant without re-projecting. The view's manifest L
    fingerprint must match the tenant's factor — a mismatch raises
    ``TenantFingerprintError`` (the typed signal that the view was built
    under a different metric: rebuild or fix the factor instead)."""
    t = router.tenant(name)
    try:
        view = load_index(view_dir, expect_L=t.L,
                          registry=router.registry)
    except ValueError as e:
        raise TenantFingerprintError(
            f"tenant {name!r}: persisted view at {view_dir} was not "
            f"built under this tenant's factor: {e}") from e
    ids_path = os.path.join(view_dir, "ids.npz")
    if os.path.isfile(ids_path):
        with np.load(ids_path) as z:
            ids = z["ids"].astype(np.int64)
    else:                           # bare save_index dir: dense view
        ids = np.arange(view.size, dtype=np.int64)
    if ids.shape[0] != view.size:
        raise TenantError(
            f"tenant {name!r}: ids map has {ids.shape[0]} entries for a "
            f"{view.size}-row view at {view_dir}")
    with router._lock:
        router._attach_view(t, t.name, None, view, ids)
        if router.scheduler is not None:
            router.scheduler.add_route(t.name, t.engine)
    router._g_warm.set(1, tenant=name)
    return t
