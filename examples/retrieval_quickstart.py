"""Retrieval quickstart: train a metric, index a gallery, query neighbors.

Run:  PYTHONPATH=src python examples/retrieval_quickstart.py

The end product of DML training is only realized at query time: nearest
neighbors under M = L^T L. This example learns L on pair constraints
(paper Eq. 4), pre-projects a gallery once (ExactIndex), and shows that
top-k neighbors under the learned metric are far more class-pure than
Euclidean neighbors on the same data. A low-rank detour trains a
rectangular (8, 64) factor (`l_rank`) on the same pairs and serves the
same gallery from ~7x less projected memory at near-square class
purity. It then swaps the same engine onto
the cluster-pruned IVFIndex and shows near-identical neighbors while
scanning a fraction of the gallery per query, and onto the
product-quantized IVFPQIndex — the same probes over uint8 residual codes
(~8x less segment memory), with an exact re-rank recovering the
quantization loss. Finally it walks the
mutable-gallery lifecycle: stream rows in and out (MutableIndex), compact
the delta, snapshot to disk and reload bit-for-bit, and hot-swap the
metric — starting from the identity (Euclidean) factor and swapping in
the trained L without rebuilding from raw data, the trainer -> server
loop.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dml
from repro.core.ps.trainer import train_dml_single
from repro.data import pairs as pairdata
from repro.serve import (ExactIndex, IVFIndex, IVFPQIndex, MutableIndex,
                         RetrievalEngine, load_index, recall_at_k,
                         save_index)


def purity(labels, query_labels, neighbor_ids):
    """Mean fraction of retrieved neighbors sharing the query's class."""
    return float(np.mean(labels[neighbor_ids] == query_labels[:, None]))


def main():
    # class signal in a small subspace, Euclidean-dominating noise elsewhere
    cfg = pairdata.PairDatasetConfig(
        n_samples=4000, feat_dim=64, n_classes=8, kind="noisy_subspace",
        noise=0.5, seed=0)
    feats, labels = pairdata.make_features(cfg)
    train_pairs, _ = pairdata.train_eval_split(
        cfg, n_train_sim=4000, n_train_dis=4000,
        n_eval_sim=500, n_eval_dis=500)

    dml_cfg = dml.DMLConfig(feat_dim=64, proj_dim=32)
    L, history = train_dml_single(dml_cfg, train_pairs, steps=300,
                                  batch_size=512, lr=2e-2, seed=0)
    print(f"objective: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    # gallery = first 3500 points; queries = the held-out tail
    gallery, g_labels = feats[:3500], labels[:3500]
    queries, q_labels = feats[3500:], labels[3500:]

    # amortize the metric once, then serve
    index = ExactIndex.build(L, jnp.asarray(gallery))
    engine = RetrievalEngine(index, k_top=10)
    _, nbrs = engine.search(queries)
    p_learned = purity(g_labels, q_labels, nbrs)

    # Euclidean baseline = identity metric over the same gallery
    eye = jnp.eye(64, dtype=jnp.float32)
    _, nbrs_e = RetrievalEngine(ExactIndex.build(eye, jnp.asarray(gallery)),
                                k_top=10).search(queries)
    p_euclid = purity(g_labels, q_labels, nbrs_e)

    print(f"neighbor class purity@10: learned {p_learned:.3f} "
          f"vs euclidean {p_euclid:.3f} (chance {1 / 8:.3f})")
    print(f"engine: {engine.stats()}")
    assert p_learned > p_euclid

    # --- low-rank L: same contract, a fraction of the memory -------------
    # l_rank trains a genuinely rectangular (d', D) factor directly on
    # the pair objective (M = L^T L is PSD at any rank — no projection
    # step), and every projected artifact downstream is sized d', so the
    # serving gallery shrinks by ~D/d'
    from repro.obs import index_memory

    L_sq, _ = train_dml_single(dml.DMLConfig(feat_dim=64, l_rank=64),
                               train_pairs, steps=300, batch_size=512,
                               lr=2e-2, seed=0)
    L_lr, _ = train_dml_single(dml.DMLConfig(feat_dim=64, l_rank=8),
                               train_pairs, steps=300, batch_size=512,
                               lr=2e-2, seed=0)
    idx_sq = ExactIndex.build(L_sq, jnp.asarray(gallery))
    idx_lr = ExactIndex.build(L_lr, jnp.asarray(gallery))
    mem_sq = index_memory(idx_sq)["gallery"]
    mem_lr = index_memory(idx_lr)["gallery"]
    _, nbrs_sq = RetrievalEngine(idx_sq, k_top=10).search(queries)
    _, nbrs_lr = RetrievalEngine(idx_lr, k_top=10).search(queries)
    r_lr = recall_at_k(nbrs_lr, nbrs_sq)
    p_lr = purity(g_labels, q_labels, nbrs_lr)
    print(f"low-rank L {tuple(np.shape(L_lr))} vs square "
          f"{tuple(np.shape(L_sq))}: projected gallery "
          f"{mem_lr / 1e3:.0f} kB vs {mem_sq / 1e3:.0f} kB "
          f"({mem_sq / mem_lr:.1f}x smaller), recall@10 vs square-L "
          f"neighbors {r_lr:.3f}, purity {p_lr:.3f}")
    assert mem_sq / mem_lr >= 4.0       # d' = D/8 -> ~7x measured
    assert p_lr > p_euclid              # rank 8 still beats Euclidean

    # same engine API, cluster-pruned backend: scan nprobe of n_clusters
    # gallery segments per query instead of all 3500 rows
    ivf = IVFIndex.build(L, jnp.asarray(gallery), n_clusters=16, nprobe=4)
    _, nbrs_ivf = RetrievalEngine(ivf, k_top=10).search(queries)
    recall = recall_at_k(nbrs_ivf, nbrs)
    p_ivf = purity(g_labels, q_labels, nbrs_ivf)
    print(f"ivf (nprobe {ivf.nprobe}/{ivf.n_clusters}, <= "
          f"{ivf.nprobe * ivf.cap} of {ivf.size} rows/query): "
          f"recall@10 vs exact {recall:.3f}, purity {p_ivf:.3f}")
    assert recall > 0.8

    # --- product-quantized segments: same probes, ~8x fewer bytes --------
    # each scanned row is n_subspaces uint8 codes (of its residual to the
    # cluster centroid) + one f32, scored via per-query ADC lookup tables;
    # the top rerank_depth candidates re-score exactly at full precision
    pq = IVFPQIndex.build(L, jnp.asarray(gallery), n_clusters=16,
                          nprobe=4, n_subspaces=8, bits=8,
                          rerank_depth=30)
    ivf_bytes = ivf.gp_pad.nbytes + ivf.gn_pad.nbytes
    pq_bytes = pq.codes_pad.nbytes + pq.t_pad.nbytes
    print(f"ivfpq segment memory: {pq_bytes / 1e3:.0f} kB vs IVF "
          f"{ivf_bytes / 1e3:.0f} kB ({ivf_bytes / pq_bytes:.1f}x "
          f"smaller; {pq.pq.code_bytes} code bytes/row)")
    _, nbrs_raw = pq.topk(queries, 10, rerank=0)       # raw ADC order
    _, nbrs_rr = pq.topk(queries, 10)                  # + exact rerank
    r_raw = recall_at_k(np.asarray(nbrs_raw), nbrs)
    r_rr = recall_at_k(np.asarray(nbrs_rr), nbrs)
    print(f"ivfpq recall@10 vs exact: {r_raw:.3f} raw ADC -> {r_rr:.3f} "
          f"with rerank {pq.rerank_depth} (quantization error recovered; "
          f"remaining loss is probe-limited, same as IVF)")
    assert r_rr >= r_raw and r_rr > 0.8

    # --- mutable gallery: stream rows, compact, snapshot, hot-swap -------
    # start from the identity metric (= Euclidean serving) and keep the
    # raw rows so the trained L can be swapped in later without touching
    # the original feature store
    mut = MutableIndex.build(eye, gallery, base="exact", retain_raw=True)
    live_engine = RetrievalEngine(mut, k_top=10)

    new_ids = mut.upsert(queries[:100])         # tail rows join the gallery
    mut.delete(np.arange(50))                   # first 50 retire
    d_self, n_self = live_engine.search(queries[0])
    print(f"mutable: size {mut.size} (delta {mut.delta_rows}, "
          f"tombstones {mut.tombstones}), upserted row is its own "
          f"nearest neighbor: {n_self[0] == new_ids[0]} "
          f"(dist {d_self[0]:.2g})")
    assert n_self[0] == new_ids[0]              # dist 0 to itself
    assert not np.isin(np.arange(50), n_self).any(), "deleted row served"

    mut.compact()                               # delta folds into the base
    _, n_compacted = live_engine.search(queries[0])
    assert np.array_equal(n_compacted, n_self)  # same answers, zero delta

    with tempfile.TemporaryDirectory() as snap:
        save_index(mut, snap)                   # restartable: npz + manifest
        restored = load_index(snap, expect_L=eye)
        _, n_restored = RetrievalEngine(restored, k_top=10) \
            .search(queries[0])
        assert np.array_equal(n_restored, n_self), "snapshot drifted"
        print(f"snapshot round-trip: top-k identical, "
              f"version {restored.version}")

    # the trainer -> server loop: swap the trained metric in, in place.
    # external ids are stable, so one label table covers original gallery
    # rows (ids 0..3499) and the upserted ones (ids 3500..3599)
    labels_by_id = np.concatenate([g_labels, q_labels[:100]])
    q_rest, ql_rest = queries[100:], q_labels[100:]
    _, nbrs_eye = live_engine.search(q_rest)
    p_eye = purity(labels_by_id, ql_rest, nbrs_eye)
    mut.swap_metric(L)                          # re-projects retained raw
    _, nbrs_swap = live_engine.search(q_rest)
    p_swap = purity(labels_by_id, ql_rest, nbrs_swap)
    print(f"metric hot-swap: purity@10 {p_eye:.3f} (euclidean) -> "
          f"{p_swap:.3f} (trained L), no raw-gallery rebuild")
    assert p_swap > p_eye


if __name__ == "__main__":
    main()
