"""Quickstart: learn a Mahalanobis metric with the paper's Eq. 4 objective.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import dml
from repro.core.ps.trainer import train_dml_single
from repro.data import pairs as pairdata


def main():
    # class-structured features where Euclidean distance is misleading
    cfg = pairdata.PairDatasetConfig(
        n_samples=2000, feat_dim=64, n_classes=8, kind="noisy_subspace",
        noise=0.5, seed=0)
    train_pairs, eval_pairs = pairdata.train_eval_split(
        cfg, n_train_sim=4000, n_train_dis=4000,
        n_eval_sim=1000, n_eval_dis=1000)

    # the paper's reformulated objective:  M = L^T L,  hinge on dissimilars
    dml_cfg = dml.DMLConfig(feat_dim=64, proj_dim=32, lam=1.0, margin=1.0)
    L, history = train_dml_single(dml_cfg, train_pairs, steps=300,
                                  batch_size=512, lr=2e-2, seed=0)
    print(f"objective: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    xs, ys = jnp.asarray(eval_pairs["xs"]), jnp.asarray(eval_pairs["ys"])
    labels = jnp.asarray(eval_pairs["sim"])
    ap_learned = float(dml.average_precision(dml.pair_scores(L, xs, ys), labels))
    ap_euclid = float(dml.average_precision(
        dml.pair_scores_euclidean(xs, ys), labels))
    print(f"held-out AP: learned metric {ap_learned:.3f} "
          f"vs euclidean {ap_euclid:.3f}")
    assert ap_learned > ap_euclid


if __name__ == "__main__":
    main()
