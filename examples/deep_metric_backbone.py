"""Beyond-paper: end-to-end deep metric learning — a transformer backbone's
pooled embeddings feed the paper's Eq. 4 metric head; backbone and L train
jointly (DESIGN.md §4 mode 3). Demonstrates the DML objective as a
first-class loss over any assigned architecture.

Run:  PYTHONPATH=src python examples/deep_metric_backbone.py [--arch smollm-135m]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import dml
from repro.models import build_model
from repro.optim import adam, apply_updates


def make_class_batches(vocab, n_classes, batch, seqlen, seed=0):
    """Token sequences whose class is encoded in token statistics."""
    rng = np.random.RandomState(seed)
    protos = rng.randint(0, vocab, size=(n_classes, seqlen))
    while True:
        cls = rng.randint(0, n_classes, size=batch)
        toks = protos[cls].copy()
        flip = rng.rand(batch, seqlen) < 0.3
        toks[flip] = rng.randint(0, vocab, size=int(flip.sum()))
        yield jnp.asarray(toks.astype(np.int32)), jnp.asarray(cls)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="smollm-135m")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    dml_cfg = dml.DMLConfig(feat_dim=cfg.d_model, proj_dim=cfg.d_model // 2)

    rng = jax.random.PRNGKey(0)
    params = {"backbone": model.init(rng),
              "L": dml.init_params(dml_cfg, jax.random.fold_in(rng, 1))}

    def loss_fn(params, toks, cls):
        emb = model.embed_pool(params["backbone"], {"tokens": toks})
        # in-batch pairs: same class -> similar
        B = emb.shape[0]
        xs = jnp.repeat(emb, B, axis=0)
        ys = jnp.tile(emb, (B, 1))
        sim = (jnp.repeat(cls, B) == jnp.tile(cls, (B,))).astype(jnp.int32)
        # mask out self-pairs by weight (boolean indexing is not jittable)
        keep = (~jnp.eye(B, dtype=bool).reshape(-1)).astype(jnp.float32)
        per_pair = dml.pair_losses(params["L"], xs, ys, sim,
                                   lam=dml_cfg.lam, margin=dml_cfg.margin)
        return jnp.sum(per_pair * keep) / jnp.sum(keep)

    opt = adam(3e-4)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, toks, cls):
        loss, g = jax.value_and_grad(loss_fn)(params, toks, cls)
        updates, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    batches = make_class_batches(cfg.vocab_size, 6, 16, 24)
    first = last = None
    for t in range(args.steps):
        toks, cls = next(batches)
        params, opt_state, loss = step(params, opt_state, toks, cls)
        first = float(loss) if first is None else first
        last = float(loss)
        if t % 10 == 0:
            print(f"step {t}: joint DML loss {last:.4f}", flush=True)
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first
    print("backbone + metric head trained jointly: OK")


if __name__ == "__main__":
    main()
