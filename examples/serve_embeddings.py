"""Serving example: batched requests -> backbone decode/embedding -> metric
retrieval with the tiled pairwise-distance Pallas kernel.

A tiny corpus is embedded once; each request batch is embedded and ranked
against the corpus under the learned Mahalanobis metric.

Run:  PYTHONPATH=src python examples/serve_embeddings.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import dml
from repro.kernels.pairwise_dist import metric_sqdist_matrix
from repro.models import build_model


def main():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dml_cfg = dml.DMLConfig(feat_dim=cfg.d_model, proj_dim=64)
    L = dml.init_params(dml_cfg, jax.random.PRNGKey(7))

    embed = jax.jit(lambda p, toks: model.embed_pool(p, {"tokens": toks}))

    rng = np.random.RandomState(0)
    corpus_tokens = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (64, 32)).astype(np.int32))
    corpus_emb = embed(params, corpus_tokens)
    print(f"corpus embedded: {corpus_emb.shape}")

    # batched request loop (the serving pattern: fixed-shape batches, jitted)
    for batch_id in range(3):
        req_tokens = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (8, 32)).astype(np.int32))
        t0 = time.perf_counter()
        req_emb = embed(params, req_tokens)
        D = metric_sqdist_matrix(L, req_emb, corpus_emb)   # Pallas kernel
        top = jnp.argsort(D, axis=1)[:, :5]
        dt = (time.perf_counter() - t0) * 1e3
        print(f"batch {batch_id}: {req_emb.shape[0]} requests in {dt:.1f}ms; "
              f"top-1 ids {np.asarray(top[:, 0])}")
        assert np.isfinite(np.asarray(D)).all()

    print("serving loop OK")


if __name__ == "__main__":
    main()
