"""End-to-end driver: train the paper's ImageNet-1M metric (21.5M params,
d=21504, k=1000 — Table 1's third row) for a few hundred steps with the
index-based pair pipeline, lr schedule, checkpointing, and optionally the
fused Pallas loss kernel or the multi-worker PS trainer.

Pairs are stored as INDICES into the feature store — at the paper's scale
(200M pairs x 21.5k dims) materialized pairs would be tens of terabytes.

Run:  PYTHONPATH=src python examples/train_imnet1m_dml.py \
          [--steps 300] [--workers 1] [--sync local --tau 8] [--fused]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.configs import dml_paper
from repro.core import dml, losses
from repro.core.ps import sync as ps_sync
from repro.data import pairs as pairdata
from repro.optim import sgd, schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--sync", type=str, default="bsp",
                    choices=["bsp", "local", "ssp"])
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--batch", type=int, default=500)
    ap.add_argument("--samples", type=int, default=10000,
                    help="synthetic stand-in for the 1M LLC images")
    ap.add_argument("--fused", action="store_true",
                    help="use the Pallas fused pair-loss kernel (interpret)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_imnet1m")
    args = ap.parse_args()

    exp = dml_paper.IMNET_1M
    print(f"config: d={exp.dml.feat_dim} k={exp.dml.proj_dim} "
          f"params={exp.dml.feat_dim*exp.dml.proj_dim/1e6:.1f}M "
          f"(paper Table 1: 21.5M)")

    data_cfg = pairdata.PairDatasetConfig(
        n_samples=args.samples, feat_dim=exp.dml.feat_dim, n_classes=100,
        kind="noisy_subspace", noise=0.8, seed=0)
    print("generating LLC-like features (noisy-subspace variant: class "
          "signal in a d/8 subspace + dominant noise dims, so raw Euclidean "
          "fails — the regime the paper targets)...", flush=True)
    features, labels = pairdata.make_features(data_cfg)
    n_hold = args.samples // 5
    train_idx = pairdata.sample_pair_indices(labels[:-n_hold], 50_000,
                                             50_000, seed=1)
    eval_idx = pairdata.sample_pair_indices(labels[-n_hold:], 5_000, 5_000,
                                            seed=2)
    hold = features[-n_hold:]
    eval_pairs = {"xs": hold[eval_idx["a"]], "ys": hold[eval_idx["b"]],
                  "sim": eval_idx["sim"]}

    opt = sgd(schedules.inverse_time(args.lr, 1e-3))
    t0 = time.time()
    hist = []

    if args.workers > 1:
        # partition pair indices over workers (paper §4.1) and run the SPMD
        # PS trainer under the chosen consistency model
        n = train_idx["sim"].shape[0]
        shards = np.array_split(np.arange(n), args.workers)
        streams = [pairdata.pair_batches_from_indices(
            features[:-n_hold],
            {k: v[s] for k, v in train_idx.items()},
            args.batch, seed=10 + i) for i, s in enumerate(shards)]
        ps_cfg = ps_sync.PSConfig(n_workers=args.workers, sync=args.sync,
                                  tau=args.tau, staleness=max(2, args.tau))
        mesh = ps_sync.make_worker_mesh(args.workers)
        L0 = dml.init_params(exp.dml, jax.random.PRNGKey(0))
        state = ps_sync.init_state(opt, L0, ps_cfg)
        step_fn = ps_sync.make_train_step(
            lambda p, b: losses.dml_pair_loss(p, b, lam=exp.dml.lam,
                                              margin=exp.dml.margin),
            opt, ps_cfg, mesh)
        for t in range(args.steps):
            batch = {k: jnp.stack([b[k] for b in
                                   [next(s) for s in streams]])
                     for k in ("xs", "ys", "sim")}
            state, metrics = step_fn(state, batch)
            hist.append({"step": t, "loss": float(metrics["loss"])})
            if t % 20 == 0:
                print(f"  step {t}: loss={hist[-1]['loss']:.4f}", flush=True)
        L = ps_sync.worker_mean(state.params)
    else:
        if args.fused:
            from repro.kernels.dml_pair import dml_pair_loss_fused
            loss_fn = lambda p, b: (dml_pair_loss_fused(
                p, b["xs"], b["ys"], b["sim"], exp.dml.lam,
                exp.dml.margin), {})
        else:
            loss_fn = lambda p, b: losses.dml_pair_loss(
                p, b, lam=exp.dml.lam, margin=exp.dml.margin)
        L = dml.init_params(exp.dml, jax.random.PRNGKey(0))
        # scale-aware init: bring initial ||Lz||^2 to O(margin) so both the
        # similar pull and the dissimilar hinge are active from step 0
        probe = next(pairdata.pair_batches_from_indices(
            features[:-n_hold], train_idx, 256, seed=99))
        d2 = float(jnp.mean(dml.mahalanobis_sqdist(L, probe["xs"], probe["ys"])))
        L = L * jnp.sqrt(2.0 * exp.dml.margin / max(d2, 1e-9))
        print(f"  init rescale: mean d2 {d2:.1f} -> ~{2*exp.dml.margin}")
        opt_state = opt.init(L)

        @jax.jit
        def step(L, opt_state, batch):
            (loss, _), g = jax.value_and_grad(
                lambda p, b: loss_fn(p, b), has_aux=True)(L, batch)
            updates, opt_state = opt.update(g, opt_state, L)
            return L + updates, opt_state, loss

        stream = pairdata.pair_batches_from_indices(
            features[:-n_hold], train_idx, args.batch, seed=0)
        for t in range(args.steps):
            L, opt_state, loss = step(L, opt_state, next(stream))
            hist.append({"step": t, "loss": float(loss)})
            if t % 20 == 0:
                print(f"  step {t}: loss={hist[-1]['loss']:.4f}", flush=True)

    wall = time.time() - t0
    print(f"trained {args.steps} steps in {wall:.0f}s "
          f"({wall/args.steps*1e3:.0f} ms/step) "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    save_checkpoint(args.ckpt, step=args.steps, tree={"L": L})
    restored, _ = restore_checkpoint(args.ckpt, {"L": L})
    np.testing.assert_array_equal(np.asarray(restored["L"]), np.asarray(L))
    print(f"checkpoint round-trip OK -> {args.ckpt}")

    xs, ys = jnp.asarray(eval_pairs["xs"]), jnp.asarray(eval_pairs["ys"])
    lab = jnp.asarray(eval_pairs["sim"])
    ap_l = float(dml.average_precision(dml.pair_scores(L, xs, ys), lab))
    ap_e = float(dml.average_precision(dml.pair_scores_euclidean(xs, ys), lab))
    print(f"held-out AP: learned {ap_l:.3f} vs euclidean {ap_e:.3f} "
          f"(paper Fig. 4c: learned metric ≫ euclidean)")


if __name__ == "__main__":
    main()
