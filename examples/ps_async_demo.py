"""Asynchronous parameter-server demo (the paper's §4.2 system, in-process).

One server thread + P worker threads with real message queues; workers never
block on the server (best-effort). Prints the loss trace interleaving and
the per-worker contribution — the same machinery benchmarks/fig2+fig3 use.

Run:  PYTHONPATH=src python examples/ps_async_demo.py [workers]
"""

import sys

import jax
import numpy as np

from repro.core import dml
from repro.core.ps import simulator
from repro.data import pairs as pairdata


def main():
    P = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    data_cfg = pairdata.PairDatasetConfig(
        n_samples=1000, feat_dim=48, n_classes=6, kind="noisy_subspace",
        seed=0)
    train_pairs, eval_pairs = pairdata.train_eval_split(
        data_cfg, 3000, 3000, 500, 500)
    dml_cfg = dml.DMLConfig(feat_dim=48, proj_dim=24)
    L0 = np.asarray(dml.init_params(dml_cfg, jax.random.PRNGKey(0)))

    cfg = simulator.AsyncPSConfig(n_workers=P, lr=1e-2, batch_size=256,
                                  steps_per_worker=120)
    L, trace = simulator.run_async_dml(cfg, train_pairs, L0)

    print(f"{len(trace)} gradient pushes from {P} workers")
    for t, wid, loss in trace[:6]:
        print(f"  t={t*1e3:7.1f}ms worker={wid} minibatch_loss={loss:.3f}")
    print("  ...")
    for t, wid, loss in trace[-3:]:
        print(f"  t={t*1e3:7.1f}ms worker={wid} minibatch_loss={loss:.3f}")

    per_worker = {w: sum(1 for _, wid, _ in trace if wid == w)
                  for w in range(P)}
    print("pushes per worker:", per_worker)

    import jax.numpy as jnp
    xs, ys = jnp.asarray(eval_pairs["xs"]), jnp.asarray(eval_pairs["ys"])
    lab = jnp.asarray(eval_pairs["sim"])
    ap = float(dml.average_precision(
        dml.pair_scores(jnp.asarray(L), xs, ys), lab))
    print(f"held-out AP after async training: {ap:.3f}")


if __name__ == "__main__":
    main()
